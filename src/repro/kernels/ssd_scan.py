"""ssd_scan — Mamba2 chunked SSD as a Pallas TPU kernel.

Grid = (batch, heads, chunks); the chunk dimension is the sequentially-
executed trailing grid dim, so the inter-chunk SSM state ``h (P, N)`` lives
in VMEM scratch across the whole sequence sweep for one (b, head) pair.
Per chunk the kernel computes the intra-chunk quadratic form (three MXU
matmuls over (Q×Q)/(Q×N)/(Q×P) tiles) plus the inter-chunk contribution
from the carried state, then updates the state — the same algorithm as
``repro.models.ssm.ssd_chunked`` (the jnp oracle), but with the state
resident in VMEM instead of rematerialized through HBM each chunk.

VMEM working set per step (full-size config Q=256, P=64, N=128, f32):
x (Q,P) + B,C (Q,N) + decay tables (Q,Q) + h (P,N) ≈ 0.6 MiB — comfortable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,    # (1, q, 1, p)
    dt_ref,   # (1, q, 1)
    a_ref,    # (1, 1)  — this head's A (negative)
    b_ref,    # (1, q, n)
    c_ref,    # (1, q, n)
    y_ref,    # (1, q, 1, p)
    hout_ref, # (1, 1, p, n) final state (written on last chunk)
    h_scr,    # (p, n) VMEM carried state
    *,
    nchunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (q, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (q,)
    a = a_ref[0, 0].astype(jnp.float32)             # scalar
    bm = b_ref[0].astype(jnp.float32)               # (q, n)
    cm = c_ref[0].astype(jnp.float32)               # (q, n)
    q = x.shape[0]

    da = dt * a                                     # (q,) log-decay
    seg = jnp.cumsum(da)                            # inclusive

    # ---- intra-chunk quadratic form -----------------------------------
    li = seg[:, None]
    lj = seg[None, :]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    )
    gam = jnp.exp(jnp.where(mask, li - lj, -jnp.inf))          # (q, q)
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                           # (q, q) MXU
    w = cb * gam * dt[None, :]
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                           # (q, p) MXU

    # ---- inter-chunk contribution from carried state -------------------
    into = jnp.exp(seg)                                         # (q,)
    ch = jax.lax.dot_general(
        cm, h_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                           # (q, p) MXU
    y = y_intra + ch * into[:, None]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # ---- state update ----------------------------------------------------
    tail = jnp.exp(seg[-1] - seg) * dt                          # (q,)
    st = jax.lax.dot_general(
        x * tail[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                           # (p, n) MXU
    h_scr[...] = h_scr[...] * jnp.exp(seg[-1]) + st

    @pl.when(ic == nchunks - 1)
    def _flush():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,   # (B, L, NH, P)
    dt: jax.Array,  # (B, L, NH)
    a: jax.Array,   # (NH,) negative
    bm: jax.Array,  # (B, L, N)
    cm: jax.Array,  # (B, L, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD; returns (y (B,L,NH,P), final state (B,NH,P,N))."""
    b, l, nh, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q
    a2 = a.reshape(nh, 1)

    y, hf = pl.pallas_call(
        functools.partial(_ssd_kernel, nchunks=nc),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, q, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, q, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, q, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, nh, p), x.dtype),
            jax.ShapeDtypeStruct((b, nh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, bm, cm)
    return y, hf
