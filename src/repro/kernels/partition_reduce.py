"""partition_reduce — the paper's ``compute_partition`` at the VMEM level.

The SplIter idea expressed as a TPU kernel (DESIGN.md §2, layer L3): the
*grid iterates the blocks of a partition* while the reduction accumulator
stays resident in VMEM; one ``pallas_call`` per partition regardless of how
many HBM blocks compose it.  Block size (HBM layout granularity) is thereby
decoupled from kernel-invocation granularity — exactly the paper's
decoupling, one level down.

Two ops, matching the paper's memory-bound applications:

* :func:`partition_histogram` — scatter-free MXU histogram: each block's
  values are compared against bin edges (one-hot via two comparisons) and
  accumulated with a matmul; the (bins,) accumulator never leaves VMEM
  until the final grid step.

* :func:`partition_kmeans` — fused Lloyd partial step: per block, squared
  distances to centroids via MXU matmul, hard assignment, one-hot matmul
  accumulation of per-centroid sums and counts in VMEM.

* :func:`partition_histogramdd` — the d-dimensional generalization used by
  the histogram app's fused lowering: rows are digitized per dimension,
  combined into a flat ``bins**d`` cell index, and accumulated scatter-free
  via a one-hot matmul; the flat-grid accumulator stays in VMEM across the
  partition's blocks.  Bit-exact against the per-block
  ``histogramdd_block`` + sum-combine path (integer counts, float32
  accumulation is exact below 2**24).

Inputs are the partition's stacked blocks ``(nblocks, rows, d)`` — i.e.
``Partition.stacked()`` — so the engine can hand a partition straight to
the kernel.  The execution layer reaches these through the kernel registry
(``repro.api.kernels``): lowering a ``SplIter(fusion="pallas")`` plan emits
one such call per same-shape run of a partition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------


def _hist_kernel(x_ref, o_ref, acc, *, bins, lo, hi, nblocks):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0].astype(jnp.float32)            # (rows, d) — one HBM block
    rows, d = x.shape
    width = (hi - lo) / bins
    # one-hot bin membership, matmul-accumulated (scatter-free histogram):
    # edges e_j = lo + j*width ; x in bin j  <=>  e_j <= x < e_{j+1}
    edges = lo + width * jax.lax.broadcasted_iota(jnp.float32, (1, bins), 1)
    xf = x.reshape(rows * d, 1)
    onehot = ((xf >= edges) & (xf < edges + width)).astype(jnp.float32)
    # clamp outliers into edge bins (matches jnp.clip digitize semantics)
    first = (xf < lo + width).astype(jnp.float32)
    last = (xf >= hi - width).astype(jnp.float32)
    onehot = jnp.maximum(onehot, jnp.concatenate(
        [first, jnp.zeros((rows * d, bins - 2), jnp.float32), last], axis=1
    ))
    ones = jnp.ones((1, rows * d), jnp.float32)
    acc[...] += jax.lax.dot_general(
        ones, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (1, bins)

    @pl.when(ib == nblocks - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bins", "lo", "hi", "interpret"))
def partition_histogram(
    stacked: jax.Array,  # (nblocks, rows, d)
    *,
    bins: int = 128,
    lo: float = 0.0,
    hi: float = 1.0,
    interpret: bool = True,
) -> jax.Array:
    """Per-dimension-flattened value histogram of a whole partition → (bins,)."""
    nb, rows, d = stacked.shape
    out = pl.pallas_call(
        functools.partial(
            _hist_kernel, bins=bins, lo=lo, hi=hi, nblocks=nb
        ),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bins), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bins), jnp.float32)],
        interpret=interpret,
    )(stacked)
    return out[0]


# ---------------------------------------------------------------------------
# d-dimensional histogram (the histogram app's block fn, fused)
# ---------------------------------------------------------------------------


def _histdd_kernel(x_ref, o_ref, acc, *, bins, lo, hi, nblocks):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0].astype(jnp.float32)            # (rows, d) — one HBM block
    rows, d = x.shape
    # digitize per dimension exactly like histogramdd_block (truncate + clip)
    scaled = (x - lo) / (hi - lo) * bins
    idx = jnp.clip(scaled.astype(jnp.int32), 0, bins - 1)        # (rows, d)
    # flat cell id: row-major over the (bins,)*d grid (static unroll over d —
    # no captured weight constants, which pallas_call rejects)
    flat = jnp.zeros((rows, 1), jnp.int32)
    for k in range(d):
        flat = flat * bins + idx[:, k : k + 1]                   # (rows, 1)
    cells = bins**d
    onehot = (
        flat == jax.lax.broadcasted_iota(jnp.int32, (rows, cells), 1)
    ).astype(jnp.float32)                        # (rows, cells)
    ones = jnp.ones((1, rows), jnp.float32)
    acc[...] += jax.lax.dot_general(
        ones, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (1, cells)

    @pl.when(ib == nblocks - 1)
    def _flush():
        o_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("bins", "lo", "hi", "interpret"))
def partition_histogramdd(
    stacked: jax.Array,  # (nblocks, rows, d)
    *,
    bins: int = 8,
    lo: float = 0.0,
    hi: float = 1.0,
    interpret: bool = True,
) -> jax.Array:
    """d-dimensional histogram of a whole partition → ``(bins,)*d`` int32.

    Equals ``sum(histogramdd_block(b) for b in blocks)`` bit-exactly — the
    contract the kernel registry requires for fused/generic interchange.
    """
    nb, rows, d = stacked.shape
    cells = bins**d
    out = pl.pallas_call(
        functools.partial(_histdd_kernel, bins=bins, lo=lo, hi=hi, nblocks=nb),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, cells), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, cells), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.float32)],
        interpret=interpret,
    )(stacked)
    return out[0].astype(jnp.int32).reshape((bins,) * d)


# ---------------------------------------------------------------------------
# k-means partial step
# ---------------------------------------------------------------------------


def _kmeans_kernel(x_ref, c_ref, sums_ref, counts_ref, acc_s, acc_c, *, nblocks):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        acc_c[...] = jnp.zeros_like(acc_c)

    x = x_ref[0].astype(jnp.float32)             # (rows, d)
    c = c_ref[...].astype(jnp.float32)           # (k, d)
    # d2 = |x|^2 - 2 x·c^T + |c|^2 ; |x|^2 constant per row -> drop for argmin
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (rows, k) MXU
    d2 = jnp.sum(c * c, axis=1)[None, :] - 2.0 * xc
    assign = jnp.argmin(d2, axis=1)               # (rows,)
    k = c.shape[0]
    onehot = (
        assign[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
    ).astype(jnp.float32)                         # (rows, k)
    acc_s[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (k, d)
    acc_c[...] += jnp.sum(onehot, axis=0, keepdims=True)  # (1, k)

    @pl.when(ib == nblocks - 1)
    def _flush():
        sums_ref[...] = acc_s[...]
        counts_ref[...] = acc_c[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def partition_kmeans(
    stacked: jax.Array,   # (nblocks, rows, d)
    centers: jax.Array,   # (k, d)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused Lloyd partial step over a partition → (sums (k,d), counts (k,))."""
    nb, rows, d = stacked.shape
    k = centers.shape[0]
    sums, counts = pl.pallas_call(
        functools.partial(_kmeans_kernel, nblocks=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, rows, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k, d), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(stacked, centers)
    return sums, counts[0]
