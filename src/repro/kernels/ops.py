"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to "not on TPU": kernels execute through the Pallas
interpreter on CPU (correctness validation, this container) and compile to
Mosaic on real TPU backends.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.partition_reduce import (
    partition_histogram as _hist,
    partition_kmeans as _kmeans,
)
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash(
        q, k, v,
        causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_default_interpret(),
    )


def partition_histogram(stacked, *, bins=128, lo=0.0, hi=1.0):
    return _hist(stacked, bins=bins, lo=lo, hi=hi, interpret=_default_interpret())


def partition_kmeans(stacked, centers):
    return _kmeans(stacked, centers, interpret=_default_interpret())


def ssd_scan(x, dt, a, bm, cm, *, chunk=256):
    return _ssd(x, dt, a, bm, cm, chunk=chunk, interpret=_default_interpret())


__all__ = ["flash_attention", "partition_histogram", "partition_kmeans", "ssd_scan"]
