"""Pure-jnp oracles for every Pallas kernel (the `assert_allclose` targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_reference


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """GQA attention, materialized scores (B,Lq,H,D)."""
    b, lq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, lq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) / np.sqrt(d)
    qpos = jnp.arange(lq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((lq, k.shape[1]), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, lq, h, d)


def histogram_ref(stacked: jax.Array, *, bins: int, lo: float, hi: float) -> jax.Array:
    """Value histogram over all elements of the stacked partition → (bins,)."""
    x = stacked.reshape(-1)
    idx = jnp.clip(((x - lo) / (hi - lo) * bins).astype(jnp.int32), 0, bins - 1)
    return jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)


def kmeans_ref(stacked: jax.Array, centers: jax.Array):
    """Lloyd partial step over the whole partition → (sums, counts)."""
    x = stacked.reshape(-1, stacked.shape[-1])
    d2 = (
        jnp.sum(x * x, 1)[:, None]
        - 2.0 * x @ centers.T
        + jnp.sum(centers * centers, 1)[None, :]
    )
    onehot = jax.nn.one_hot(jnp.argmin(d2, 1), centers.shape[0], dtype=jnp.float32)
    return onehot.T @ x.astype(jnp.float32), jnp.sum(onehot, 0)


def ssd_ref(x, dt, a, bm, cm):
    """Sequential SSD recurrence → (y, final_state)."""
    return ssd_reference(x, dt, a, bm, cm)
