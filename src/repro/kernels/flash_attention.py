"""Flash attention (causal, GQA, optional sliding window) — Pallas TPU kernel.

Online-softmax blocked attention.  Grid = (batch, q_heads, q_blocks,
kv_blocks); the TPU grid is executed sequentially over the trailing dim, so
the running max / denominator / accumulator live in VMEM scratch across the
kv sweep for one (b, h, iq) triple and are flushed to the output on the
last kv step.

VMEM tiling (BlockSpec):
  q   (1, 1, bq, d)   indexed (b, h, iq)
  k,v (1, 1, bk, d)   indexed (b, h // group, ik)   ← GQA: KV heads mapped
  o   (1, 1, bq, d)   indexed (b, h, iq)

`bq`/`bk` default to 128 (MXU-aligned); `d` is the full head_dim (≤ 256 —
fits VMEM comfortably: 3·128·128·4B ≈ 200 KiB working set per step).

Causal masking is positional (absolute q/kv indices), so the kernel also
serves prefill-with-offset.  A sliding window adds a lower bound on kv
positions.  Out-of-range kv *blocks* contribute via masking; a production
refinement would skip them in the index map (noted in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref,    # (1, 1, bq, d)
    k_ref,    # (1, 1, bk, d)
    v_ref,    # (1, 1, bk, d)
    o_ref,    # (1, 1, bq, d)
    m_scr,    # (bq, 1) f32 running max
    l_scr,    # (bq, 1) f32 running denom
    acc_scr,  # (bq, d) f32 accumulator
    *,
    scale: float,
    causal: bool,
    window: int,
    bq: int,
    bk: int,
    kv_steps: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                       # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)       # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    p = jnp.where(m_new == NEG_INF, 0.0, jnp.exp(s - m_new))  # (bq, bk)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == kv_steps - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Lq, H, D)
    k: jax.Array,  # (B, Lk, Hkv, D)
    v: jax.Array,  # (B, Lk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked attention; returns (B, Lq, H, D) in q.dtype.

    ``interpret=True`` runs the kernel body in the Pallas interpreter (CPU
    validation); on TPU pass ``interpret=False``.
    """
    b, lq, h, d = q.shape
    _, lk, hkv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0, (lq, bq, lk, bk)
    kv_steps = lk // bk
    scale = 1.0 / np.sqrt(d)

    # layout: (B, H, L, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        kv_steps=kv_steps,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, lq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
