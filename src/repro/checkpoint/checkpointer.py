"""Sharded, async, atomic checkpointing with elastic restore.

Layout of one checkpoint step directory::

    <root>/step_000123/
        MANIFEST.json     # tree structure, leaf shapes/dtypes, extras
        leaf_00000.npy    # one file per pytree leaf (host-gathered shard set)
        ...
    <root>/step_000123.COMMITTED   # atomic commit marker (rename-last)

Design points for the 1000-node story:

* **atomicity** — writers fill a ``.tmp`` directory, fsync, then rename and
  only then drop the COMMITTED marker; a crashed save can never be mistaken
  for a valid checkpoint (restore scans for the newest COMMITTED step).
* **async** — ``save(..., blocking=False)`` snapshots to host RAM
  (device_get) and hands the file IO to a writer thread so the train loop
  resumes immediately; ``wait()`` joins before the next save or exit.
* **elastic restore** — leaves are stored *unsharded* (host-gathered);
  ``restore(..., shardings=...)`` re-places them under ANY mesh, so a run
  saved on N hosts restarts on M (tested N→M for M ∈ {1,2,4}).  At real
  scale the per-leaf files become per-shard files + a layout map; the
  manifest already records everything needed.
* **self-describing** — restore needs no template pytree: the manifest
  rebuilds the tree (dicts/lists/tuples/dataclass names), so a rescue tool
  can inspect a checkpoint without the model code.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_COMMIT_SUFFIX = ".COMMITTED"


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [p for p, _ in paths], leaves, treedef


class Checkpointer:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._writer: threading.Thread | None = None

    # ------------------------------------------------------------- save --

    def save(
        self,
        step: int,
        tree: Any,
        *,
        extras: dict[str, Any] | None = None,
        blocking: bool = True,
    ) -> None:
        """Snapshot ``tree`` (pytree of arrays) + JSON-able ``extras``."""
        self.wait()
        paths, leaves, treedef = _flatten_with_paths(tree)
        # host snapshot NOW (so training can mutate buffers after we return)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        try:  # proto treedef only for builtin-container trees (debug aid);
            # restore never needs it (it rebuilds from the template)
            treedef_hex = treedef.serialize_using_proto().hex()
        except (ValueError, AttributeError):
            treedef_hex = None
        manifest = {
            "step": step,
            "treedef": treedef_hex,
            "paths": ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p) for p in paths],
            "leaves": [
                {"shape": list(l.shape), "dtype": str(l.dtype)} for l in host_leaves
            ],
            "extras": extras or {},
            "time": time.time(),
        }

        def write():
            name = f"step_{step:09d}"
            tmp = os.path.join(self.root, name + ".tmp")
            final = os.path.join(self.root, name)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            # commit marker LAST — crash before this line = checkpoint absent
            with open(final + _COMMIT_SUFFIX, "w") as f:
                f.write(name)

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # ---------------------------------------------------------- restore --

    def latest_step(self) -> int | None:
        steps = []
        for f in os.listdir(self.root):
            if f.endswith(_COMMIT_SUFFIX):
                steps.append(int(f[len("step_") : -len(_COMMIT_SUFFIX)]))
        return max(steps) if steps else None

    def load_manifest(self, step: int | None = None) -> tuple[dict[str, Any], int]:
        """Read a committed step's MANIFEST.json without loading leaves.

        The template-free inspection path: a
        :class:`~repro.api.jobserver.JobServer` snapshots scheduler state
        as pure-JSON ``extras`` (no array leaves at all), so resume only
        needs the manifest.  Returns ``(manifest, step)``; raises
        ``FileNotFoundError`` when no committed step exists — a ``.tmp``
        directory or a step directory without its COMMITTED marker is never
        considered (the crash-mid-save contract).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        if not os.path.exists(d + _COMMIT_SUFFIX):
            raise FileNotFoundError(f"uncommitted checkpoint {d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            return json.load(f), step

    def restore(
        self,
        template: Any,
        *,
        step: int | None = None,
        shardings: Any | None = None,
    ) -> tuple[Any, dict[str, Any], int]:
        """Restore into the structure of ``template`` (shapes must match).

        ``shardings`` (optional pytree of NamedSharding / Sharding) re-places
        every leaf for the CURRENT mesh — the elastic-restart path.
        Returns (tree, extras, step).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no committed checkpoint under {self.root}"
        d = os.path.join(self.root, f"step_{step:09d}")
        assert os.path.exists(d + _COMMIT_SUFFIX), f"uncommitted checkpoint {d}"
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)

        leaves, treedef = jax.tree_util.tree_flatten(template)
        assert len(leaves) == len(manifest["leaves"]), (
            len(leaves),
            len(manifest["leaves"]),
        )
        out_leaves = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, (tmpl, meta) in enumerate(zip(leaves, manifest["leaves"])):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            assert list(arr.shape) == list(meta["shape"])
            assert tuple(arr.shape) == tuple(tmpl.shape), (
                manifest["paths"][i],
                arr.shape,
                tmpl.shape,
            )
            if shard_leaves is not None:
                out_leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out_leaves.append(jax.device_put(arr.astype(tmpl.dtype)))
        tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
        return tree, manifest["extras"], step

    # ------------------------------------------------------------- gc ----

    def keep_last(self, n: int) -> None:
        """Delete all but the newest ``n`` committed checkpoints."""
        steps = sorted(
            int(f[len("step_") : -len(_COMMIT_SUFFIX)])
            for f in os.listdir(self.root)
            if f.endswith(_COMMIT_SUFFIX)
        )
        for s in steps[:-n] if n else steps:
            name = os.path.join(self.root, f"step_{s:09d}")
            os.remove(name + _COMMIT_SUFFIX)
            shutil.rmtree(name, ignore_errors=True)
