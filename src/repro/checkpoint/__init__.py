"""Checkpoint substrate: sharded, async, atomic, elastic-restorable."""

from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["Checkpointer"]
