"""Task engine — per-block dispatch (baseline) vs fused per-partition execution.

This is where the granularity coupling the paper attacks becomes concrete.
In COMPSs/Dask a *task* is a scheduler-dispatched unit; in JAX the analogue
is one invocation of a compiled executable (host dispatch + launch).  The
engine runs map-reduce style workloads in four modes:

``baseline``      one dispatch per block (paper Listing 4) + a merge task.
``spliter``       SplIter (paper Listing 5): one dispatch per *partition*;
                  the task iterates its local blocks with a fused
                  ``lax.scan`` carrying the partition-local reduction —
                  zero data movement, locality preserved.
``spliter_mat``   SplIter with materialized partitions (paper §7): the
                  partition's blocks are concatenated *locally* and the
                  task consumes one contiguous buffer.
``rechunk``       the competitor: materialize the dataset at one block per
                  location (inter-location traffic!), then per-block tasks.

Every mode reports dispatch counts, traced-compile counts, wall time and
bytes moved so benchmarks can reproduce the paper's figures and the
structural claims (C1–C4 in DESIGN.md).

Iterative applications (k-means, Cascade SVM) pass a persistent
:class:`TaskEngine` so task *definitions* are traced once and re-dispatched
every iteration — matching how COMPSs/Dask register a task once and invoke
it many times.  Loop-carried values (e.g. centroids) travel as traced
``extra_args``, never as baked-in constants.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Hashable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import BlockedArray
from repro.core.rechunk import rechunk
from repro.core.spliter import Partition, spliter

__all__ = ["EngineReport", "TaskEngine", "run_map_reduce", "MODES"]

MODES = ("baseline", "spliter", "spliter_mat", "rechunk")

BlockFn = Callable[..., Any]           # (*blocks, *extra_args) -> partial pytree
CombineFn = Callable[[Any, Any], Any]  # (acc, partial) -> acc, associative


@dataclasses.dataclass
class EngineReport:
    """Cost accounting for one workload execution."""

    mode: str
    dispatches: int = 0          # compiled-executable invocations (the "tasks")
    merges: int = 0              # merge-task dispatches (subset of dispatches)
    traces: int = 0              # distinct traced/compiled programs
    bytes_moved: int = 0         # inter-location traffic (rechunk only; SplIter: 0)
    wall_s: float = 0.0

    def as_row(self) -> dict:
        return dataclasses.asdict(self)

    def __iadd__(self, other: "EngineReport") -> "EngineReport":
        self.dispatches += other.dispatches
        self.merges += other.merges
        self.traces += other.traces
        self.bytes_moved += other.bytes_moved
        self.wall_s += other.wall_s
        return self


class TaskEngine:
    """Caches compiled 'tasks' and counts dispatches (the @task decorator)."""

    def __init__(self):
        self._cache: dict[Hashable, Callable] = {}
        self.report = EngineReport(mode="?")

    def new_report(self, mode: str) -> EngineReport:
        self.report = EngineReport(mode=mode)
        return self.report

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        """Register ``fn`` as a task (jitted once per key, dispatch-counted)."""
        key = key if key is not None else fn
        if key not in self._cache:
            jfn = jax.jit(fn)

            def dispatch(*args, _jfn=jfn, _self=self, **kw):
                _self.report.dispatches += 1
                return _jfn(*args, **kw)

            self._cache[key] = dispatch
            self.report.traces += 1
        return self._cache[key]


def _merge_task(engine: TaskEngine, combine: CombineFn, partials: list[Any]) -> Any:
    """Single merge task over the stacked partials (paper's @reduction task)."""

    def merge(stacked):
        def body(acc, p):
            return combine(acc, p), None

        first = jax.tree.map(lambda s: s[0], stacked)
        rest = jax.tree.map(lambda s: s[1:], stacked)
        acc, _ = jax.lax.scan(body, first, rest)
        return acc

    if len(partials) == 1:
        return partials[0]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *partials)
    out = engine.task(merge, key=("merge", combine))(stacked)
    engine.report.merges += 1
    return out


def run_map_reduce(
    inputs: Sequence[BlockedArray],
    block_fn: BlockFn,
    combine: CombineFn,
    *,
    mode: str = "spliter",
    partitions_per_location: int = 1,
    extra_args: tuple = (),
    engine: TaskEngine | None = None,
) -> tuple[Any, EngineReport]:
    """Run ``reduce(combine, [block_fn(*blocks_i, *extra_args) for i])``.

    ``inputs`` are blocking-aligned collections (e.g. Cascade SVM's points
    and labels).  ``extra_args`` are traced operands shared by every task
    (e.g. current centroids) — they are *arguments*, not constants, so
    iterative callers re-dispatch without re-tracing.

    Returns ``(result, report)``.  The result is mode-independent up to
    floating-point reassociation (hypothesis-tested invariant).
    """
    assert mode in MODES, mode
    x0 = inputs[0]
    for a in inputs[1:]:
        assert a.num_blocks == x0.num_blocks, "inputs must be blocking-aligned"
        assert np.array_equal(a.placements, x0.placements)
    engine = engine or TaskEngine()
    report = engine.new_report(mode)
    n_in = len(inputs)

    t0 = time.perf_counter()

    if mode in ("baseline", "rechunk"):
        arrs = list(inputs)
        if mode == "rechunk":
            # One block per location: the competitor's granularity fix.
            target = math.ceil(x0.num_rows / x0.num_locations)
            new_arrs = []
            for a in arrs:
                na, st = rechunk(a, target)
                report.bytes_moved += st.bytes_moved
                new_arrs.append(na)
            arrs = new_arrs
        t = engine.task(block_fn, key=("block", block_fn))
        partials = [
            t(*(a.blocks[i] for a in arrs), *extra_args)
            for i in range(arrs[0].num_blocks)
        ]
        result = _merge_task(engine, combine, partials)

    elif mode in ("spliter", "spliter_mat"):
        parts = spliter(x0, partitions_per_location=partitions_per_location)

        def partition_task(*operands):
            data, extra = operands[:n_in], operands[n_in:]

            def body(acc, blk):
                p = block_fn(*blk, *extra)
                return combine(acc, p), None

            first = block_fn(*(s[0] for s in data), *extra)
            acc, _ = jax.lax.scan(body, first, jax.tree.map(lambda s: s[1:], data))
            return acc

        partials = []
        for part in parts:
            zipped = [
                Partition(source=a, location=part.location, block_ids=part.block_ids)
                for a in inputs
            ]
            if mode == "spliter_mat":
                # Materialized partition (paper §7): local concat, one call.
                bufs = tuple(z.materialize() for z in zipped)
                t = engine.task(block_fn, key=("block", block_fn))
                partials.append(t(*bufs, *extra_args))
            else:
                # Fused iteration: ONE dispatch scanning the local blocks,
                # carrying the partition-local reduction (paper Listing 5's
                # compute_partition, expressed as lax.scan).  Ragged tails
                # (dataset size not a multiple of the block size — normal
                # for Dask/dislib arrays) scan per same-shape run, so a
                # partition costs at most one extra dispatch for its tail.
                by_shape: dict[tuple, list[int]] = {}
                for j, bid in enumerate(part.block_ids):
                    shp = x0.blocks[bid].shape
                    by_shape.setdefault(shp, []).append(j)
                t = engine.task(
                    partition_task, key=("part", block_fn, combine, n_in)
                )
                for idxs in by_shape.values():
                    stacks = tuple(
                        jnp.stack([z.blocks[j] for j in idxs], axis=0)
                        for z in zipped
                    )
                    partials.append(t(*stacks, *extra_args))
        result = _merge_task(engine, combine, partials)

    else:  # pragma: no cover
        raise ValueError(mode)

    result = jax.block_until_ready(result)
    report.wall_s = time.perf_counter() - t0
    return result, report
