"""Task engine — jit-cached task registration and cost accounting.

In COMPSs/Dask a *task* is a scheduler-dispatched unit; in JAX the analogue
is one invocation of a compiled executable (host dispatch + launch).  The
:class:`TaskEngine` registers functions as tasks (jitted once per key) and
counts dispatches, traces, merges and bytes moved in an
:class:`EngineReport`, so benchmarks can reproduce the paper's figures and
the structural claims (C1–C4 in DESIGN.md).

Execution strategies live in ``repro.api``: a lazy
:class:`~repro.api.Collection` builds an :class:`~repro.api.ExecutionPlan`
which an :class:`~repro.api.Executor` backend (``LocalExecutor``,
``ThreadedExecutor``) runs under a typed
:class:`~repro.api.ExecutionPolicy` (``Baseline`` / ``SplIter`` /
``Rechunk``).  Iterative applications pass a persistent executor so task
*definitions* are traced once and re-dispatched every iteration — matching
how COMPSs/Dask register a task once and invoke it many times.
Loop-carried values (e.g. centroids) travel as traced ``extra_args``,
never as baked-in constants.

:func:`run_map_reduce` — the seed's stringly-typed entry point — remains
only as a deprecated shim over the plan-based layer.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import warnings
from typing import Any, Callable, Hashable, Sequence

import jax

from repro.core.blocked import BlockedArray

__all__ = ["EngineReport", "TaskEngine", "run_map_reduce", "MODES"]

# Legacy mode strings, accepted by the deprecated shim (and mapped onto the
# typed policies by repro.api.as_policy).
MODES = ("baseline", "spliter", "spliter_mat", "rechunk")

BlockFn = Callable[..., Any]           # (*blocks, *extra_args) -> partial pytree
CombineFn = Callable[[Any, Any], Any]  # (acc, partial) -> acc, associative


#: Per-field aggregation rules for :meth:`EngineReport.__iadd__` /
#: :meth:`EngineReport.merge` — the single registry every aggregation and
#: (de)serialization path derives from ``dataclasses.fields``, so a newly
#: added counter (e.g. ``shm_bytes``) is summed, merged and JSON
#: round-tripped without touching any hand-listed key set.
#:   "sum"    — counters/timers: add (the default for unlisted fields)
#:   "latest" — settings: keep the other window's value when non-zero
#:   "label"  — identity strings: untouched by ``+=`` (merge() joins them)
_FIELD_RULES = {
    "mode": "label",
    "granularity": "latest",
}


@dataclasses.dataclass
class EngineReport:
    """Cost accounting for one workload execution."""

    mode: str
    dispatches: int = 0          # compiled-executable invocations (the "tasks")
    merges: int = 0              # merge-task dispatches (subset of dispatches)
    traces: int = 0              # distinct traced/compiled programs (this report)
    bytes_moved: int = 0         # inter-location traffic (rechunk only; SplIter: 0)
    wall_s: float = 0.0
    granularity: int = 0         # partitions_per_location in effect (SplIter; 0: n/a)
    retunes: int = 0             # autotuner granularity changes entering this window
    bytes_loaded: int = 0        # chunk-store spill reads during this window
    bytes_spilled: int = 0       # chunk-store spill writes (evictions of dirty chunks)
    prefetch_hits: int = 0       # chunk gets served by an earlier prefetch
    remote_dispatches: int = 0   # dispatches executed in a worker process (cluster)
    ipc_bytes: int = 0           # serialized control-channel bytes (cluster); block
    #                              payloads travel out-of-band via shm_bytes
    shm_bytes: int = 0           # bytes copied into shared-memory segments (cluster)
    retries: int = 0             # units replayed after a worker death (cluster)
    overlapped_launches: int = 0  # units admitted while an earlier execute was
    #                               still unresolved (pipelined iteration)
    steals: int = 0              # units moved to an idle worker by work stealing
    scale_events: int = 0        # autoscaler pool changes (grow + shrink)
    p2p_bytes: int = 0           # partial bytes exchanged worker→worker over
    #                              shared memory instead of through the driver
    driver_merge_bytes: int = 0  # partial bytes the driver itself folded

    def as_row(self) -> dict:
        return dataclasses.asdict(self)

    def merge(self, other: "EngineReport") -> "EngineReport":
        """A NEW report aggregating two windows (neither input is mutated).

        The JobServer uses this to fold a resumed job's segments into one
        per-job report: counters and wall time sum, ``granularity`` keeps
        the most recent non-zero value (the setting the run ended on), and
        the mode string joins when the segments disagree.
        """
        mode = self.mode if self.mode == other.mode else f"{self.mode}+{other.mode}"
        out = dataclasses.replace(self, mode=mode)
        out += other
        return out

    def to_json(self) -> str:
        """Serialize for the client channel / journal (see :meth:`from_json`)."""
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "EngineReport":
        """Rebuild a report serialized by :meth:`to_json`.

        Unknown keys are ignored so a journal written by a newer build (with
        extra counters) still replays; missing keys take field defaults.
        """
        data = json.loads(payload)
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in data.items() if k in names})

    def __iadd__(self, other: "EngineReport") -> "EngineReport":
        for f in dataclasses.fields(self):
            rule = _FIELD_RULES.get(f.name, "sum")
            if rule == "sum":
                setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
            elif rule == "latest":
                value = getattr(other, f.name)
                if value:
                    setattr(self, f.name, value)
            # "label" fields (mode) are merge()'s business, untouched here
        return self


class TaskEngine:
    """Caches compiled 'tasks' and counts dispatches (the @task decorator).

    Trace accounting: ``traces_total`` counts every distinct registration
    over the engine's lifetime; each report shows the *delta* accrued during
    its own window (snapshotted at :meth:`new_report`), so iterative
    workloads attribute traces to the iteration that actually paid them
    instead of crediting whichever report happened to be current.

    Counter updates are lock-protected: ``ThreadedExecutor`` dispatches
    tasks from one worker thread per location.

    Pipelined executes (DESIGN.md §14) overlap several reports' windows in
    time, so "the current report" can no longer be a single engine-wide
    slot: a worker thread running iteration *k*'s units must bill *k*'s
    report even while the submitting thread has already moved
    ``self.report`` on to *k+1*.  :meth:`bind_report` installs a
    *thread-local* billing target; :attr:`current_report` is what every
    counter site charges — the bound report when one is active on the
    calling thread, else ``self.report`` (so the synchronous path and the
    JobServer's per-job segment swap are untouched).
    """

    def __init__(self):
        self._cache: dict[Hashable, Callable] = {}
        self._lock = threading.Lock()
        self._local = threading.local()
        self.traces_total = 0
        self._trace_mark = 0
        self.report = EngineReport(mode="?")

    def new_report(self, mode: str) -> EngineReport:
        self.report = EngineReport(mode=mode)
        self._trace_mark = self.traces_total
        return self.report

    @property
    def current_report(self) -> EngineReport:
        """The report this thread bills: bound (pipelined) or engine-wide."""
        bound = getattr(self._local, "report", None)
        return bound if bound is not None else self.report

    @contextlib.contextmanager
    def bind_report(self, report: EngineReport):
        """Bill this thread's dispatches/traces/merges to ``report``."""
        prev = getattr(self._local, "report", None)
        self._local.report = report
        try:
            yield report
        finally:
            self._local.report = prev

    def task(self, fn: Callable, *, key: Hashable = None) -> Callable:
        """Register ``fn`` as a task (jitted once per key, dispatch-counted)."""
        key = key if key is not None else fn
        if key not in self._cache:
            jfn = jax.jit(fn)

            def dispatch(*args, _jfn=jfn, _self=self, **kw):
                with _self._lock:
                    _self.current_report.dispatches += 1
                return _jfn(*args, **kw)

            self._cache[key] = dispatch
            with self._lock:
                self.traces_total += 1
                rep = self.current_report
                if rep is self.report:
                    rep.traces = self.traces_total - self._trace_mark
                else:
                    # Bound (pipelined) window: the engine-wide trace mark
                    # belongs to whichever synchronous report is current, so
                    # credit the newly paid trace to the bound report alone.
                    rep.traces += 1
        return self._cache[key]


def run_map_reduce(
    inputs: Sequence[BlockedArray],
    block_fn: BlockFn,
    combine: CombineFn,
    *,
    mode: str = "spliter",
    partitions_per_location: int = 1,
    extra_args: tuple = (),
    engine: TaskEngine | None = None,
) -> tuple[Any, EngineReport]:
    """DEPRECATED shim over the plan-based layer — use :mod:`repro.api`.

    ``run_map_reduce(inputs, f, c, mode=m)`` is equivalent to::

        Collection.from_blocked(inputs).split(as_policy(m))
            .map_blocks(f, extra_args=...).reduce(c)
            .compute(executor=LocalExecutor(engine=engine))

    Returns ``(result, report)`` exactly as before; results are
    policy-independent up to floating-point reassociation.
    """
    warnings.warn(
        "run_map_reduce(mode=...) is deprecated; build a plan with "
        "repro.api.Collection and run it with an Executor "
        "(see DESIGN.md §8 for the migration table)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import Collection, as_policy
    from repro.api.executors import _default_local

    policy = as_policy(mode, partitions_per_location=partitions_per_location)
    res = (
        Collection.from_blocked(list(inputs))
        .split(policy)
        .map_blocks(block_fn, extra_args=tuple(extra_args))
        .reduce(combine)
        .compute(executor=_default_local(engine=engine))
    )
    return res.value, res.report
