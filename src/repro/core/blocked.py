"""Blocked distributed collections — the substrate the SplIter operates on.

The paper's frameworks (COMPSs+dataClay, Dask) hold a dataset as a set of
*blocks* distributed across *nodes*.  Here a :class:`BlockedArray` holds a
dataset as a sequence of row-blocks, each with an explicit *placement* — a
logical location id that models "which node/backend holds this block".

Two execution substrates consume this metadata:

* the paper-faithful task engine (``repro.core.engine``) which dispatches
  work per block / per partition and uses placements for locality, and
* the mesh substrate (``repro.data.pipeline``) where placement is derived
  from a ``jax.sharding.NamedSharding`` over a device mesh (the production
  path), so placement queries are exact — the JAX analogue of Dask
  ``who_has`` / dataClay metadata lookups.

Blocks are dense ``(block_rows, *row_shape)`` arrays.  The *global order* of
rows (paper §4.1) is ``block_id``-major: row ``r`` of block ``b`` has global
index ``offset[b] + r``.

**Out-of-core blocks.**  A block may also be a
:class:`~repro.api.chunkstore.ChunkRef` — a metadata handle (same
``shape``/``dtype``/``nbytes`` surface as an array) whose buffer lives in a
:class:`~repro.api.chunkstore.ChunkStore` and materializes only at dispatch
time.  All geometry here (placements, row offsets, ``blocks_at``) is
metadata-only and works on refs without loading a byte; anything that needs
buffer contents goes through :meth:`BlockedArray.block` /
:meth:`BlockedArray.iter_blocks`, which resolve refs transparently.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockedArray",
    "PlacementPolicy",
    "round_robin_placement",
    "contiguous_placement",
]

# A placement policy maps (num_blocks, num_locations) -> per-block location ids.
PlacementPolicy = Callable[[int, int], np.ndarray]


def round_robin_placement(num_blocks: int, num_locations: int) -> np.ndarray:
    """Block *b* lives on location ``b % L`` — models Dask's default scatter."""
    return np.arange(num_blocks, dtype=np.int32) % num_locations


def contiguous_placement(num_blocks: int, num_locations: int) -> np.ndarray:
    """Consecutive runs of blocks per location — models dislib/dataClay fills."""
    per = math.ceil(num_blocks / num_locations)
    return (np.arange(num_blocks, dtype=np.int32) // per).clip(0, num_locations - 1)


@dataclasses.dataclass(frozen=True)
class BlockedArray:
    """A row-blocked dataset with explicit block placement.

    Attributes:
      blocks: tuple of ``(rows_b, *row_shape)`` jax arrays — or
        :class:`~repro.api.chunkstore.ChunkRef` handles to store-held
        buffers — in global order.
      placements: int32 array ``(num_blocks,)`` — logical location per block.
      num_locations: number of logical locations (nodes/backends/devices).
    """

    blocks: tuple
    placements: np.ndarray
    num_locations: int

    def __post_init__(self):
        assert len(self.blocks) == len(self.placements), (
            len(self.blocks),
            len(self.placements),
        )
        assert len(self.blocks) > 0, "empty BlockedArray"
        row_shape = self.blocks[0].shape[1:]
        for b in self.blocks:
            assert b.shape[1:] == row_shape, "inconsistent row shapes"

    # -- construction ------------------------------------------------------

    @classmethod
    def from_array(
        cls,
        x: jax.Array,
        block_rows: int,
        *,
        num_locations: int = 1,
        policy: PlacementPolicy = contiguous_placement,
        store=None,
    ) -> "BlockedArray":
        """Split ``x`` along axis 0 into blocks of ``block_rows`` rows.

        The final block may be short (ragged tail), exactly like a Dask
        array whose shape is not a multiple of the chunk size.  With
        ``store`` (a :class:`~repro.api.chunkstore.ChunkStore`) each block
        is ``put`` into the store and the collection holds
        :class:`~repro.api.chunkstore.ChunkRef` handles instead of
        resident buffers — a ``DiskStore`` then bounds how much of the
        dataset is in memory at once.
        """
        n = x.shape[0]
        assert block_rows >= 1
        nb = math.ceil(n / block_rows)
        blocks = tuple(x[i * block_rows : (i + 1) * block_rows] for i in range(nb))
        if store is not None:
            blocks = tuple(store.put(b) for b in blocks)
        return cls(blocks, policy(nb, num_locations), num_locations)

    @classmethod
    def from_blocks(
        cls,
        blocks: Sequence[jax.Array],
        placements: Sequence[int] | np.ndarray,
        num_locations: int,
    ) -> "BlockedArray":
        return cls(tuple(blocks), np.asarray(placements, np.int32), num_locations)

    # -- geometry ----------------------------------------------------------

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    @property
    def row_shape(self) -> tuple[int, ...]:
        return self.blocks[0].shape[1:]

    @property
    def dtype(self):
        return self.blocks[0].dtype

    @property
    def block_rows(self) -> tuple[int, ...]:
        return tuple(b.shape[0] for b in self.blocks)

    @property
    def num_rows(self) -> int:
        return sum(self.block_rows)

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(b.shape)) * b.dtype.itemsize for b in self.blocks)

    @property
    def uniform(self) -> bool:
        """True when every block has the same number of rows."""
        rows = self.block_rows
        return all(r == rows[0] for r in rows)

    def row_offsets(self) -> np.ndarray:
        """Global row index of the first row of each block (paper §4.1)."""
        return np.concatenate([[0], np.cumsum(self.block_rows)[:-1]]).astype(np.int64)

    def blocks_at(self, location: int) -> list[int]:
        """The block ids resident at ``location`` — the `who_has` query."""
        return [int(i) for i in np.nonzero(self.placements == location)[0]]

    # -- buffer access (resolves chunk refs) --------------------------------

    def block(self, i: int) -> jax.Array:
        """Block ``i``'s buffer, resolving a chunk ref if necessary."""
        from repro.api.chunkstore import resolve_chunk

        return resolve_chunk(self.blocks[i])

    def iter_blocks(self):
        """Yield resolved block buffers in global order, one at a time.

        The streaming-friendly accessor: out-of-core consumers touch one
        block's bytes at a time instead of holding ``self.blocks``.
        """
        for i in range(len(self.blocks)):
            yield self.block(i)

    @property
    def is_chunked(self) -> bool:
        """True when any block is a store-held chunk reference."""
        from repro.api.chunkstore import ChunkRef

        return any(isinstance(b, ChunkRef) for b in self.blocks)

    def to_store(self, store) -> "BlockedArray":
        """Move every block into ``store``; same blocking, ref-backed."""
        from repro.api.chunkstore import resolve_chunk

        refs = tuple(store.put(resolve_chunk(b)) for b in self.blocks)
        return BlockedArray(refs, self.placements, self.num_locations)

    # -- conversions -------------------------------------------------------

    def collect(self) -> jax.Array:
        """Concatenate all blocks in global order (a full gather)."""
        return jnp.concatenate(list(self.iter_blocks()), axis=0)

    def stacked(self) -> jax.Array:
        """Stack uniform blocks into ``(num_blocks, block_rows, *row_shape)``."""
        assert self.uniform, "stacked() requires uniform block sizes"
        return jnp.stack(list(self.iter_blocks()), axis=0)

    def with_placements(self, placements: np.ndarray, num_locations: int) -> "BlockedArray":
        return BlockedArray(self.blocks, np.asarray(placements, np.int32), num_locations)
