"""repro.core — the paper's contribution: SplIter over blocked collections.

Public surface:

* :class:`BlockedArray` — blocked dataset with explicit placement.
* :func:`spliter` / :func:`split` — locality partitions (zero movement).
* :class:`Partition` — logical block group; ``get_indexes`` /
  ``get_item_indexes`` / ``materialize``.
* :func:`rechunk` — the materializing competitor, with traffic accounting.
* :func:`run_map_reduce`, :class:`TaskEngine` — per-block vs per-partition
  execution with dispatch accounting.
* ``repro.core.apps`` — the paper's four applications.
"""

from repro.core.blocked import (
    BlockedArray,
    contiguous_placement,
    round_robin_placement,
)
from repro.core.engine import MODES, EngineReport, TaskEngine, run_map_reduce
from repro.core.rechunk import RechunkStats, rechunk
from repro.core.spliter import Partition, split, spliter

__all__ = [
    "BlockedArray",
    "contiguous_placement",
    "round_robin_placement",
    "EngineReport",
    "TaskEngine",
    "run_map_reduce",
    "MODES",
    "RechunkStats",
    "rechunk",
    "Partition",
    "split",
    "spliter",
]
