"""repro.core — the paper's contribution: SplIter over blocked collections.

Public surface:

* :class:`BlockedArray` — blocked dataset with explicit placement.
* :func:`spliter` / :func:`split` — locality partitions (zero movement).
* :class:`Partition` — logical block group; ``get_indexes`` /
  ``get_item_indexes`` / ``materialize``.
* :func:`rechunk` — the materializing competitor, with traffic accounting.
* :class:`TaskEngine`, :class:`EngineReport` — jit-cached task registration
  with dispatch/trace/bytes accounting.
* :func:`run_map_reduce` — DEPRECATED stringly-typed shim; execution now
  lives in the plan-based ``repro.api`` layer (Collection / ExecutionPolicy
  / Executor).
* ``repro.core.apps`` — the paper's four applications (on ``repro.api``).
"""

from repro.core.blocked import (
    BlockedArray,
    contiguous_placement,
    round_robin_placement,
)
from repro.core.engine import MODES, EngineReport, TaskEngine, run_map_reduce
from repro.core.rechunk import RechunkStats, rechunk
from repro.core.spliter import Partition, split, spliter

__all__ = [
    "BlockedArray",
    "contiguous_placement",
    "round_robin_placement",
    "EngineReport",
    "TaskEngine",
    "run_map_reduce",
    "MODES",
    "RechunkStats",
    "rechunk",
    "Partition",
    "split",
    "spliter",
]
