"""SplIter — split a blocked collection into locality partitions, then iterate.

This is the paper's contribution (§4).  ``spliter(x)`` queries the placement
of every block of ``x`` and yields :class:`Partition` objects:

* a partition groups blocks that live on a **single location** (locality —
  paper: "Each partition is located in a single node");
* grouping is **logical**: a partition holds *references* to the original
  block buffers — zero data movement, zero transformation (the key contrast
  with ``rechunk``);
* the number of partitions adapts to the *computing capability* of the
  environment (paper: nodes × cores) via ``partitions_per_location``;
* ordering metadata is carried along (paper §4.1): ``get_indexes()`` returns
  the global block ids, ``get_item_indexes()`` the global row ids.

A partition can optionally be **materialized** (paper §7 future work,
implemented here): its blocks are concatenated *locally* — an intra-location
copy with no inter-node transfer — so compute-bound consumers get a
contiguous buffer (recovers the rechunk advantage observed for Cascade SVM).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import BlockedArray

__all__ = ["Partition", "spliter", "split", "stripe_local_blocks"]


@dataclasses.dataclass(frozen=True)
class Partition:
    """A logical, single-location group of blocks of a :class:`BlockedArray`.

    Holds references, never copies.  ``block_ids`` are *global* block indices
    in ascending order, mirroring the paper's partition construction (blocks
    are grouped in placement-scan order; original collection order is
    recoverable through the index accessors).
    """

    source: BlockedArray
    location: int
    block_ids: tuple[int, ...]

    # -- iteration (the "Iter" in SplIter) ----------------------------------

    def __iter__(self) -> Iterator[jax.Array]:
        for b in self.block_ids:
            yield self.source.block(b)

    def __len__(self) -> int:
        return len(self.block_ids)

    @property
    def blocks(self) -> list[jax.Array]:
        # Resolves chunk refs (out-of-core sources) one block at a time;
        # partition *construction* stays metadata-only — see spliter().
        return [self.source.block(b) for b in self.block_ids]

    @property
    def num_rows(self) -> int:
        return int(sum(self.source.block_rows[b] for b in self.block_ids))

    # -- ordering metadata (paper §4.1) --------------------------------------

    def get_indexes(self) -> list[int]:
        """Global block indices of this partition's blocks (paper Fig. 4)."""
        return list(self.block_ids)

    def get_item_indexes(self) -> np.ndarray:
        """Global row indices of every element, concatenated in block order."""
        offs = self.source.row_offsets()
        rows = self.source.block_rows
        return np.concatenate(
            [np.arange(offs[b], offs[b] + rows[b], dtype=np.int64) for b in self.block_ids]
        )

    # -- materialization (paper §7, implemented as a beyond-paper feature) ---

    def materialize(self) -> jax.Array:
        """Local concat of the partition's blocks.  Intra-location copy only."""
        return jnp.concatenate(self.blocks, axis=0)

    def stacked(self) -> jax.Array:
        """Stack (uniform blocks) into ``(k, block_rows, *row_shape)`` — the
        fused-scan input used by the task engine's per-partition execution."""
        return jnp.stack(self.blocks, axis=0)


def stripe_local_blocks(
    local: Sequence[int], partitions_per_location: int
) -> list[tuple[int, ...]]:
    """Balanced striping of one location's block ids into sub-partitions.

    The single source of truth for how ``partitions_per_location`` divides a
    location's blocks: :func:`spliter` and the executors' regroup-without-
    resplit path (``repro.api.executors``) must agree block-for-block, so a
    granularity retune that merely *regroups* an already-split collection
    yields exactly the partitions a fresh split would have produced.
    """
    k = min(partitions_per_location, len(local))
    return [tuple(local[s::k]) for s in range(k)]


def spliter(
    x: BlockedArray,
    *,
    partitions_per_location: int = 1,
) -> list[Partition]:
    """Split ``x`` into locality partitions (the paper's ``split()``).

    Queries block placement (the dataClay-metadata / Dask-``who_has``
    analogue — here :meth:`BlockedArray.blocks_at`) and groups node-local
    blocks.  ``partitions_per_location`` models the paper's adaptation to
    the computing capability (e.g. one partition per core or per socket
    instead of per node).

    Returns partitions ordered by (location, sub-partition).  Locations that
    hold no blocks yield no partitions.  Every block appears in exactly one
    partition (tested as a hypothesis invariant).
    """
    assert partitions_per_location >= 1
    parts: list[Partition] = []
    for loc in range(x.num_locations):
        local = x.blocks_at(loc)
        if not local:
            continue
        for ids in stripe_local_blocks(local, partitions_per_location):
            parts.append(Partition(source=x, location=loc, block_ids=ids))
    return parts


# The paper's listings call it ``split(experiment)``; keep that alias.
split = spliter
