"""rechunk — the materializing competitor (paper §3.2.1, §4.2).

``rechunk(x, new_block_rows)`` builds a **new** :class:`BlockedArray` whose
blocks have a different size.  Unlike the SplIter it materializes data:
rows generally cross location boundaries, so the operation *moves bytes
between locations* and temporarily doubles the footprint — exactly the costs
the paper charges against Dask's ``rechunk``.

We account those costs explicitly so benchmarks can report them next to
wall-clock: :class:`RechunkStats` counts inter-location traffic (bytes whose
source and destination locations differ) and the materialized footprint.
On the mesh substrate the same operation is a resharding ``device_put``,
whose cost shows up as collective-permute/all-to-all bytes in the lowered
HLO (see ``repro.analysis.hlo``).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.blocked import BlockedArray, contiguous_placement, PlacementPolicy

__all__ = ["rechunk", "RechunkStats"]


@dataclasses.dataclass(frozen=True)
class RechunkStats:
    """Cost accounting for one rechunk operation."""

    bytes_total: int        # full materialized size (the 2x footprint term)
    bytes_moved: int        # inter-location traffic (src loc != dst loc)
    blocks_before: int
    blocks_after: int

    @property
    def is_noop(self) -> bool:
        return self.bytes_moved == 0 and self.blocks_before == self.blocks_after


def rechunk(
    x: BlockedArray,
    new_block_rows: int,
    *,
    policy: PlacementPolicy = contiguous_placement,
) -> tuple[BlockedArray, RechunkStats]:
    """Materialize ``x`` at a new block size (global order preserved).

    Returns the new collection plus the traffic/footprint accounting.  The
    data path is a genuine gather + re-split (not a metadata trick), matching
    Dask semantics: the result is a standalone array with its own placement.
    """
    assert new_block_rows >= 1
    n = x.num_rows
    nb_new = math.ceil(n / new_block_rows)
    new_placements = policy(nb_new, x.num_locations)

    # --- movement accounting (row-granular, before touching data) ---------
    row_bytes = int(np.prod(x.row_shape)) * x.dtype.itemsize if x.row_shape else x.dtype.itemsize
    src_loc = np.repeat(x.placements, np.asarray(x.block_rows))          # (n,)
    dst_block = np.minimum(np.arange(n) // new_block_rows, nb_new - 1)
    dst_loc = new_placements[dst_block]                                   # (n,)
    moved_rows = int(np.sum(src_loc != dst_loc))
    stats = RechunkStats(
        bytes_total=n * row_bytes,
        bytes_moved=moved_rows * row_bytes,
        blocks_before=x.num_blocks,
        blocks_after=nb_new,
    )

    # --- the materialization itself ---------------------------------------
    if nb_new == x.num_blocks and all(r == new_block_rows for r in x.block_rows[:-1]):
        # Same chunking: Dask's rechunk is a no-op; keep the original buffers.
        return x.with_placements(new_placements, x.num_locations), stats

    # collect() resolves chunk-backed blocks — rechunk IS the materializing
    # competitor, so an out-of-core source pays a full gather here (and the
    # result is a plain resident array; the contrast with SplIter's
    # metadata-only split is the point).
    full = x.collect()
    blocks = tuple(
        full[i * new_block_rows : min((i + 1) * new_block_rows, n)] for i in range(nb_new)
    )
    return (
        BlockedArray(blocks, new_placements, x.num_locations),
        stats,
    )
