"""k-means / Lloyd's algorithm (paper §5.2) — iterative, memory-bound.

Per block: pairwise distances → per-centroid partial sums and counts
(``_partial_sum`` in dislib).  Merge: elementwise sum, then mean
(``_recompute_centers``).

The iterative outer loop re-uses one persistent executor: task definitions
are traced once, and the executor's prepare cache applies the split (or the
rechunk, with its traffic bill) exactly once — paper §6.3.1 "this cost is
only payed once, not for every iteration" — with no app-level special
casing.  Centroids travel as ``extra_args`` so every iteration re-dispatches
the same compiled task.

``policy=SplIter(partitions_per_location="auto")`` turns the loop into the
autotuner's natural host: early iterations probe the granularity ladder,
the cost model picks a granularity, and every retune is a logical regroup
of the already-split blocks (zero movement, zero re-splits).
:class:`KMeansResult` surfaces the per-iteration granularity trajectory and
the total retune count.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api import Collection, Executor, ExecutionPolicy, SplIter, as_policy
from repro.api.executors import _default_local
from repro.api.kernels import PartitionKernel, pallas_interpret, register_partition_kernel
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport
from repro.kernels.partition_reduce import partition_kmeans

__all__ = ["kmeans", "partial_sum_block", "KMeansResult"]


def partial_sum_block(block: jax.Array, centers: jax.Array):
    """One Lloyd E+partial-M step on a ``(rows, d)`` block.

    Returns ``(sums (k,d), counts (k,))`` — the associative partial state.
    """
    d2 = (
        jnp.sum(block * block, axis=1)[:, None]
        - 2.0 * block @ centers.T
        + jnp.sum(centers * centers, axis=1)[None, :]
    )                                                        # (rows, k)
    assign = jnp.argmin(d2, axis=1)                          # (rows,)
    k = centers.shape[0]
    one_hot = jax.nn.one_hot(assign, k, dtype=block.dtype)   # (rows, k)
    sums = one_hot.T @ block                                 # (k, d)
    counts = jnp.sum(one_hot, axis=0)                        # (k,)
    return sums, counts


def _combine(a, b):
    return a[0] + b[0], a[1] + b[1]


def _centers_of(partials):
    """Recompute centers from merged ``(sums, counts)`` partials."""
    sums, counts = partials
    return sums / jnp.maximum(counts, 1.0)[:, None]


def _kmeans_kernel_factory(args: tuple, kwargs: dict) -> PartitionKernel | None:
    """Fused-kernel factory: bare ``partial_sum_block`` (centers via extra_args)."""
    if args or kwargs:
        return None
    return PartitionKernel(
        name="partition_kmeans",
        key=("kmeans_partial",),
        fn=lambda stacked, centers: partition_kmeans(
            stacked, centers, interpret=pallas_interpret()
        ),
        supports=lambda stacked_shape, extra_args: len(extra_args) == 1,
    )


register_partition_kernel(partial_sum_block, _kmeans_kernel_factory)


@dataclasses.dataclass
class KMeansResult:
    centers: jax.Array
    iterations: int
    reports: list[EngineReport]

    @property
    def total_dispatches(self) -> int:
        return sum(r.dispatches for r in self.reports)

    @property
    def total_wall_s(self) -> float:
        return sum(r.wall_s for r in self.reports)

    @property
    def total_bytes_moved(self) -> int:
        return sum(r.bytes_moved for r in self.reports)

    @property
    def total_retunes(self) -> int:
        return sum(r.retunes for r in self.reports)

    @property
    def granularity_trajectory(self) -> list[int]:
        """partitions_per_location per iteration (0 for non-SplIter runs)."""
        return [r.granularity for r in self.reports]


def kmeans(
    x: BlockedArray,
    *,
    k: int = 8,
    iters: int = 10,
    seed: int = 0,
    policy: ExecutionPolicy | str = SplIter(),
    executor: Executor | None = None,
    pipeline: bool = False,
) -> KMeansResult:
    d = x.row_shape[0]
    centers = jax.random.uniform(jax.random.key(seed), (k, d), x.dtype)
    pol = as_policy(policy)
    ex = executor if executor is not None else _default_local()
    data = Collection.from_blocked(x).split(pol)

    reports: list[EngineReport] = []

    if pipeline:
        # Pipelined loop (DESIGN.md §14): submit iteration k+1 while k is
        # in flight; the loop-carried centers travel as a lazy Deferred
        # (``fut.map(_centers_of)``), resolved by the scheduler only when
        # a unit that needs them dispatches.  Bit-identical to the
        # barriered loop — same per-block math, same merge order.
        centers_op = centers
        futs = []
        for _ in range(iters):
            fut = (
                data.map_blocks(partial_sum_block, extra_args=(centers_op,))
                .reduce(_combine)
                .compute_async(executor=ex)
            )
            futs.append(fut)
            centers_op = fut.map(_centers_of)
        centers = centers_op.resolve() if futs else centers
        reports = [f.result().report for f in futs]
        return KMeansResult(centers=centers, iterations=iters, reports=reports)

    for _ in range(iters):
        res = (
            data.map_blocks(partial_sum_block, extra_args=(centers,))
            .reduce(_combine)
            .compute(executor=ex)
        )
        sums, counts = res.value
        centers = _centers_of((sums, counts))
        reports.append(res.report)

    return KMeansResult(centers=centers, iterations=iters, reports=reports)
