"""k-means / Lloyd's algorithm (paper §5.2) — iterative, memory-bound.

Per block: pairwise distances → per-centroid partial sums and counts
(``_partial_sum`` in dislib).  Merge: elementwise sum, then mean
(``_recompute_centers``).  The iterative outer loop re-uses the same
partitions every iteration, diluting the split cost (paper §6.3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport, TaskEngine, run_map_reduce

__all__ = ["kmeans", "partial_sum_block", "KMeansResult"]


def partial_sum_block(block: jax.Array, centers: jax.Array):
    """One Lloyd E+partial-M step on a ``(rows, d)`` block.

    Returns ``(sums (k,d), counts (k,))`` — the associative partial state.
    """
    d2 = (
        jnp.sum(block * block, axis=1)[:, None]
        - 2.0 * block @ centers.T
        + jnp.sum(centers * centers, axis=1)[None, :]
    )                                                        # (rows, k)
    assign = jnp.argmin(d2, axis=1)                          # (rows,)
    k = centers.shape[0]
    one_hot = jax.nn.one_hot(assign, k, dtype=block.dtype)   # (rows, k)
    sums = one_hot.T @ block                                 # (k, d)
    counts = jnp.sum(one_hot, axis=0)                        # (k,)
    return sums, counts


def _combine(a, b):
    return a[0] + b[0], a[1] + b[1]


@dataclasses.dataclass
class KMeansResult:
    centers: jax.Array
    iterations: int
    reports: list[EngineReport]

    @property
    def total_dispatches(self) -> int:
        return sum(r.dispatches for r in self.reports)

    @property
    def total_wall_s(self) -> float:
        return sum(r.wall_s for r in self.reports)

    @property
    def total_bytes_moved(self) -> int:
        return sum(r.bytes_moved for r in self.reports)


def kmeans(
    x: BlockedArray,
    *,
    k: int = 8,
    iters: int = 10,
    seed: int = 0,
    mode: str = "spliter",
    partitions_per_location: int = 1,
) -> KMeansResult:
    d = x.row_shape[0]
    centers = jax.random.uniform(jax.random.key(seed), (k, d), x.dtype)
    reports: list[EngineReport] = []

    # rechunk (like SplIter's split) is paid ONCE, outside the loop — paper
    # §6.3.1: "this cost is only payed once, not for every iteration".
    work = x
    eff_mode = mode
    if mode == "rechunk":
        from repro.core.rechunk import rechunk
        import math

        target = math.ceil(x.num_rows / x.num_locations)
        work, st = rechunk(x, target)
        pre = EngineReport(mode="rechunk")
        pre.bytes_moved = st.bytes_moved
        reports.append(pre)
        eff_mode = "baseline"  # per-(big-)block tasks on the rechunked array

    engine = TaskEngine()  # task definitions traced once, reused per iteration
    for _ in range(iters):
        (sums, counts), rep = run_map_reduce(
            [work],
            partial_sum_block,
            _combine,
            mode=eff_mode,
            partitions_per_location=partitions_per_location,
            extra_args=(centers,),
            engine=engine,
        )
        centers = sums / jnp.maximum(counts, 1.0)[:, None]
        reports.append(rep)

    return KMeansResult(centers=centers, iterations=iters, reports=reports)
