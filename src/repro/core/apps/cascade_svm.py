"""Cascade SVM (paper §5.3, after Graf et al.) — compute-bound, order-sensitive.

Each cascade level trains an SVM per data group and keeps its support
vectors; pairs of SV sets are unioned and retrained until one set remains;
the global loop feeds the final SVs back (few iterations).

Order sensitivity: the labels ``y`` are a *separate* blocked collection that
must stay aligned with the points ``x`` — the paper handles this with
``get_indexes`` (§4.1).  Here ``Collection.zip(x, y)`` carries both arrays
through one plan, so every :class:`~repro.api.PartitionView` yields
block-aligned (points, labels) buffers; the level-0 group list is a single
``map_partitions`` whose granularity (per block, per partition, per
rechunked block) is entirely the policy's decision.

Microkernel adaptation (DESIGN.md §2): sklearn's SMO-based SVC does not
exist on TPU; we train a bias-free RBF kernel SVM by projected gradient
ascent on the dual — O(n² d) kernel matrix + O(n²) iterations keeps the
task compute-bound, matching the paper's characterization.  "Support
vectors" are the top-m points by dual coefficient, giving static shapes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api import Collection, Executor, ExecutionPolicy, SplIter, as_policy
from repro.api.executors import _default_local
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport

__all__ = ["cascade_svm", "svc_train", "CascadeSVMResult"]


def _rbf(a: jax.Array, b: jax.Array, gamma: float) -> jax.Array:
    d2 = (
        jnp.sum(a * a, 1)[:, None]
        - 2.0 * a @ b.T
        + jnp.sum(b * b, 1)[None, :]
    )
    return jnp.exp(-gamma * d2)


def svc_train(
    x: jax.Array,
    y: jax.Array,
    *,
    c: float = 1.0,
    gamma: float = 0.5,
    steps: int = 200,
    num_sv: int,
):
    """Train a bias-free RBF-SVM; return the ``num_sv`` strongest SVs.

    Dual projected gradient:  α ← clip(α + η(1 − Q α), 0, C) with
    Q = (y yᵀ) ⊙ K.  Returns ``(sv_x, sv_y, sv_alpha)`` with static shapes.
    """
    n = x.shape[0]
    q = _rbf(x, x, gamma) * (y[:, None] * y[None, :])
    eta = 1.0 / (jnp.linalg.norm(q, ord=jnp.inf) + 1e-6)

    def body(_, alpha):
        g = 1.0 - q @ alpha
        return jnp.clip(alpha + eta * g, 0.0, c)

    alpha = jax.lax.fori_loop(0, steps, body, jnp.zeros((n,), x.dtype))
    _, top = jax.lax.top_k(alpha, min(num_sv, n))
    return x[top], y[top], alpha[top]


@dataclasses.dataclass
class CascadeSVMResult:
    sv_x: jax.Array
    sv_y: jax.Array
    sv_alpha: jax.Array
    report: EngineReport

    def decision(self, q: jax.Array, gamma: float = 0.5) -> jax.Array:
        return _rbf(q, self.sv_x, gamma) @ (self.sv_alpha * self.sv_y)


def cascade_svm(
    x: BlockedArray,
    y: BlockedArray,
    *,
    num_sv: int = 32,
    c: float = 1.0,
    gamma: float = 0.5,
    steps: int = 200,
    iterations: int = 2,
    policy: ExecutionPolicy | str = SplIter(),
    executor: Executor | None = None,
) -> CascadeSVMResult:
    """Run the cascade under an execution policy.

    ``Baseline``: level-0 trains one task per *block* (paper Listing 8).
    ``SplIter``: level-0 trains one task per *partition* on the
    locally-concatenated blocks (paper Listing 9 — the partition is
    consumed through index-aligned x/y pairs; materialization is inherent,
    so ``SplIter(materialize=True)`` coincides with ``SplIter()``).
    ``Rechunk``: materialize one block per location first (traffic!).
    """
    assert x.num_blocks == y.num_blocks
    pol = as_policy(policy)
    ex = executor if executor is not None else _default_local()

    def train_task(bx, by, feed_x, feed_y):
        ax = jnp.concatenate([bx, feed_x], 0)
        ay = jnp.concatenate([by, feed_y], 0)
        return svc_train(ax, ay, c=c, gamma=gamma, steps=steps, num_sv=num_sv)

    def merge_task(x1, y1, x2, y2):
        return svc_train(
            jnp.concatenate([x1, x2], 0),
            jnp.concatenate([y1, y2], 0),
            c=c,
            gamma=gamma,
            steps=steps,
            num_sv=num_sv,
        )

    with ex.scope(pol.mode_name) as report:
        # Level-0 group list: aligned (points, labels) buffers per task —
        # one plan, granularity decided by the policy.
        groups = (
            Collection.zip(Collection.from_blocked(x), Collection.from_blocked(y))
            .split(pol)
            .map_partitions(lambda view: view.materialized)
            .compute(executor=ex)
            .value
        )

        d = x.row_shape[0]
        feed_x = jnp.zeros((0, d), x.dtype)
        feed_y = jnp.zeros((0,), y.dtype)

        for _ in range(iterations):
            t = ex.task(train_task, key=("train", feed_x.shape))
            level = [t(bx, by, feed_x, feed_y) for bx, by in groups]
            # Binary cascade: union pairs of SV sets and retrain (Graf et al.).
            while len(level) > 1:
                nxt = []
                mt = ex.task(merge_task, key="merge")
                for i in range(0, len(level) - 1, 2):
                    (x1, y1, _), (x2, y2, _) = level[i], level[i + 1]
                    nxt.append(mt(x1, y1, x2, y2))
                    report.merges += 1
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            sv_x, sv_y, sv_a = level[0]
            feed_x, feed_y = sv_x, sv_y  # feedback loop

        # Final model: retrain on the winning SV set keeping ALL its points
        # (Graf et al.: the last cascade level's full solution is the model).
        refit = ex.task(
            lambda fx, fy: svc_train(
                fx, fy, c=c, gamma=gamma, steps=steps, num_sv=int(sv_x.shape[0])
            ),
            key=("refit", int(sv_x.shape[0])),
        )
        sv_x, sv_y, sv_a = refit(sv_x, sv_y)
        sv_x, sv_y, sv_a = jax.block_until_ready((sv_x, sv_y, sv_a))
    return CascadeSVMResult(sv_x=sv_x, sv_y=sv_y, sv_alpha=sv_a, report=report)
