"""k-Nearest Neighbors (paper §5.4) — two-stage, order-sensitive, consolidation.

*fit*: build one lookup structure per fit-block (baseline) or one per
partition (SplIter — the paper's key insight: consolidation decouples the
number of intermediate structures from the blocking and makes each lookup
structure more efficient, Figs 7/8).

*kneighbors*: every query block is looked up against every structure and the
per-structure top-k results are merged — #tasks = #structures × #query
blocks, so consolidation shrinks both the task count and the merge fan-in
(Table 1 / Fig 21).

TPU adaptation (DESIGN.md §2): sklearn KD-trees → the MXU-native structure
is the consolidated candidate *matrix*; lookup = one distance matmul + one
``top_k``.  The complexity argument transfers: merge cost scales with the
number of structures, per-structure lookup is sub-linear in its size
(top-k over one big matrix beats K-way merge of many small top-ks).

Order sensitivity: returned neighbor ids must be **global** row ids of the
fit dataset — exactly what ``Partition.get_item_indexes`` provides (§4.1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport, TaskEngine
from repro.core.spliter import spliter

__all__ = ["knn", "KNNResult"]


@dataclasses.dataclass
class KNNResult:
    distances: jax.Array  # (n_queries, k) squared distances, ascending
    indices: jax.Array    # (n_queries, k) GLOBAL fit-row ids
    report: EngineReport


def _lookup(fit_x: jax.Array, fit_ids: jax.Array, q: jax.Array, k: int):
    """Distances of ``q`` against one structure → per-query top-k (d², id)."""
    d2 = (
        jnp.sum(q * q, 1)[:, None]
        - 2.0 * q @ fit_x.T
        + jnp.sum(fit_x * fit_x, 1)[None, :]
    )
    neg, pos = jax.lax.top_k(-d2, k)          # smallest distances
    return -neg, fit_ids[pos]


def _merge(d1, i1, d2, i2, k: int):
    """Merge two top-k candidate sets (the paper's _merge_kqueries)."""
    d = jnp.concatenate([d1, d2], axis=1)
    i = jnp.concatenate([i1, i2], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(i, pos, axis=1)


def knn(
    fit: BlockedArray,
    queries: BlockedArray,
    *,
    k: int = 8,
    mode: str = "spliter",
    partitions_per_location: int = 1,
) -> KNNResult:
    engine = TaskEngine()
    report = engine.new_report(mode)
    import time

    t0 = time.perf_counter()

    # ---- fit stage: build the lookup structures --------------------------
    offs = fit.row_offsets()
    if mode in ("baseline", "rechunk"):
        wfit = fit
        if mode == "rechunk":
            import math

            from repro.core.rechunk import rechunk

            target = math.ceil(fit.num_rows / fit.num_locations)
            wfit, st = rechunk(fit, target)
            report.bytes_moved += st.bytes_moved
            offs = wfit.row_offsets()
        fit_task = engine.task(lambda b: b, key="fit_identity")
        structures = []
        for i in range(wfit.num_blocks):
            pts = fit_task(wfit.blocks[i])  # the "tree build" task
            ids = jnp.arange(offs[i], offs[i] + wfit.block_rows[i], dtype=jnp.int32)
            structures.append((pts, ids))
    elif mode in ("spliter", "spliter_mat"):
        parts = spliter(fit, partitions_per_location=partitions_per_location)
        fit_task = engine.task(
            lambda *bs: jnp.concatenate(bs, 0), key=("fit_concat",)
        )
        structures = []
        for p in parts:
            # ONE consolidated structure per partition (paper Fig. 8);
            # global row ids come from get_item_indexes (paper §4.1).
            pts = fit_task(*p.blocks)
            ids = jnp.asarray(p.get_item_indexes(), jnp.int32)
            structures.append((pts, ids))
    else:  # pragma: no cover
        raise ValueError(mode)

    # ---- kneighbors stage -------------------------------------------------
    lookup_task = engine.task(lambda f, ids, q: _lookup(f, ids, q, k), key=("lk", k))
    merge_task = engine.task(lambda a, b, c, d: _merge(a, b, c, d, k), key=("mg", k))

    out_d, out_i = [], []
    for qb in queries.blocks:
        cand = None
        for pts, ids in structures:
            r = lookup_task(pts, ids, qb)
            if cand is None:
                cand = r
            else:
                cand = merge_task(cand[0], cand[1], r[0], r[1])
                report.merges += 1
        out_d.append(cand[0])
        out_i.append(cand[1])

    distances = jnp.concatenate(out_d, 0)
    indices = jnp.concatenate(out_i, 0)
    distances, indices = jax.block_until_ready((distances, indices))
    report.wall_s = time.perf_counter() - t0
    return KNNResult(distances=distances, indices=indices, report=report)
