"""k-Nearest Neighbors (paper §5.4) — two-stage, order-sensitive, consolidation.

*fit*: build one lookup structure per fit-block (Baseline) or one per
partition (SplIter — the paper's key insight: consolidation decouples the
number of intermediate structures from the blocking and makes each lookup
structure more efficient, Figs 7/8).  Both cases are ONE
``map_partitions`` plan: under Baseline every block is its own
single-block partition, so the policy object carries the entire mode
difference.

*kneighbors*: every query block is looked up against every structure and the
per-structure top-k results are merged — #tasks = #structures × #query
blocks, so consolidation shrinks both the task count and the merge fan-in
(Table 1 / Fig 21).

TPU adaptation (DESIGN.md §2): sklearn KD-trees → the MXU-native structure
is the consolidated candidate *matrix*; lookup = one distance matmul + one
``top_k``.  The complexity argument transfers: merge cost scales with the
number of structures, per-structure lookup is sub-linear in its size
(top-k over one big matrix beats K-way merge of many small top-ks).

Order sensitivity: returned neighbor ids must be **global** row ids of the
fit dataset — exactly what ``PartitionView.item_indexes`` provides (§4.1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api import Collection, Executor, ExecutionPolicy, SplIter, as_policy
from repro.api.executors import _default_local
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport

__all__ = ["knn", "KNNResult"]


@dataclasses.dataclass
class KNNResult:
    distances: jax.Array  # (n_queries, k) squared distances, ascending
    indices: jax.Array    # (n_queries, k) GLOBAL fit-row ids
    report: EngineReport


def _lookup(fit_x: jax.Array, fit_ids: jax.Array, q: jax.Array, k: int):
    """Distances of ``q`` against one structure → per-query top-k (d², id)."""
    d2 = (
        jnp.sum(q * q, 1)[:, None]
        - 2.0 * q @ fit_x.T
        + jnp.sum(fit_x * fit_x, 1)[None, :]
    )
    neg, pos = jax.lax.top_k(-d2, k)          # smallest distances
    return -neg, fit_ids[pos]


def _merge(d1, i1, d2, i2, k: int):
    """Merge two top-k candidate sets (the paper's _merge_kqueries)."""
    d = jnp.concatenate([d1, d2], axis=1)
    i = jnp.concatenate([i1, i2], axis=1)
    neg, pos = jax.lax.top_k(-d, k)
    return -neg, jnp.take_along_axis(i, pos, axis=1)


def knn(
    fit: BlockedArray,
    queries: BlockedArray,
    *,
    k: int = 8,
    policy: ExecutionPolicy | str = SplIter(),
    executor: Executor | None = None,
) -> KNNResult:
    pol = as_policy(policy)
    ex = executor if executor is not None else _default_local()

    with ex.scope(pol.mode_name) as report:
        build_task = ex.task(lambda *bs: jnp.concatenate(bs, 0), key=("knn_fit",))

        def build_structure(view):
            # ONE consolidated structure per partition (paper Fig. 8); a
            # single-block "partition" under Baseline.  Global row ids come
            # from the view's item_indexes (paper §4.1).
            pts = build_task(*view.blocks)
            ids = jnp.asarray(view.item_indexes, jnp.int32)
            return pts, ids

        # ---- fit stage: build the lookup structures ----------------------
        structures = (
            Collection.from_blocked(fit)
            .split(pol)
            .map_partitions(build_structure)
            .compute(executor=ex)
            .value
        )

        # ---- kneighbors stage --------------------------------------------
        lookup_task = ex.task(lambda f, ids, q: _lookup(f, ids, q, k), key=("lk", k))
        merge_task = ex.task(lambda a, b, c, d: _merge(a, b, c, d, k), key=("mg", k))

        out_d, out_i = [], []
        for qb in queries.iter_blocks():
            cand = None
            for pts, ids in structures:
                r = lookup_task(pts, ids, qb)
                if cand is None:
                    cand = r
                else:
                    cand = merge_task(cand[0], cand[1], r[0], r[1])
                    report.merges += 1
            out_d.append(cand[0])
            out_i.append(cand[1])

        distances = jnp.concatenate(out_d, 0)
        indices = jnp.concatenate(out_i, 0)
        distances, indices = jax.block_until_ready((distances, indices))
    return KNNResult(distances=distances, indices=indices, report=report)
