"""n-dimensional Histogram (paper §5.1) — embarrassingly parallel, memory-bound.

Per block: ``histogramdd``; merge: summation.  The SplIter version performs
the first summation inside the fused per-partition task (locality
guaranteed), the final merge is a single reduction task — exactly paper
Listings 4/5, expressed as one plan on the :mod:`repro.api` layer.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.api import Collection, Executor, ExecutionPolicy, SplIter, as_policy
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport

__all__ = ["histogram", "histogramdd_block"]


def histogramdd_block(block: jax.Array, *, bins: int, lo: float, hi: float) -> jax.Array:
    """d-dimensional histogram of one ``(rows, d)`` block → ``(bins,)*d`` counts.

    jnp analogue of ``np.histogramdd`` with shared uniform bin edges: each
    row is digitized per-dimension and scattered into the flat grid.
    """
    rows, d = block.shape
    scaled = (block - lo) / (hi - lo) * bins
    idx = jnp.clip(scaled.astype(jnp.int32), 0, bins - 1)            # (rows, d)
    flat = jnp.zeros((), jnp.int32)
    for k in range(d):
        flat = flat * bins + idx[:, k]
    counts = jnp.zeros((bins**d,), jnp.int32).at[flat].add(1)
    return counts.reshape((bins,) * d)


def histogram(
    x: BlockedArray,
    *,
    bins: int = 8,
    lo: float = 0.0,
    hi: float = 1.0,
    policy: ExecutionPolicy | str = SplIter(),
    executor: Executor | None = None,
) -> tuple[jax.Array, EngineReport]:
    block_fn = partial(histogramdd_block, bins=bins, lo=lo, hi=hi)
    res = (
        Collection.from_blocked(x)
        .split(as_policy(policy))
        .map_blocks(block_fn)
        .reduce(lambda a, b: a + b)
        .compute(executor=executor)
    )
    return res.value, res.report
