"""n-dimensional Histogram (paper §5.1) — embarrassingly parallel, memory-bound.

Per block: ``histogramdd``; merge: summation.  The SplIter version performs
the first summation inside the fused per-partition task (locality
guaranteed), the final merge is a single reduction task — exactly paper
Listings 4/5, expressed as one plan on the :mod:`repro.api` layer.

A fused Pallas partition kernel
(:func:`repro.kernels.partition_reduce.partition_histogramdd`) is
registered for :func:`histogramdd_block`, so ``SplIter(fusion="pallas")``
lowers each partition to ONE ``pallas_call`` whose grid iterates the
partition's blocks with the flat-grid accumulator resident in VMEM.

``policy=SplIter(partitions_per_location="auto")`` works here too, but the
autotuner lives on the *executor*: pass a persistent executor across
repeated ``histogram`` calls (e.g. re-binning the same dataset) so the
probe → model → retune schedule can advance; the returned report's
``granularity`` / ``retunes`` fields expose what it chose.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.api import Collection, Executor, ExecutionPolicy, SplIter, as_policy
from repro.api.kernels import PartitionKernel, pallas_interpret, register_partition_kernel
from repro.core.blocked import BlockedArray
from repro.core.engine import EngineReport
from repro.kernels.partition_reduce import partition_histogramdd

__all__ = ["histogram", "histogramdd_block"]


def histogramdd_block(block: jax.Array, *, bins: int, lo: float, hi: float) -> jax.Array:
    """d-dimensional histogram of one ``(rows, d)`` block → ``(bins,)*d`` counts.

    jnp analogue of ``np.histogramdd`` with shared uniform bin edges: each
    row is digitized per-dimension and scattered into the flat grid.
    """
    rows, d = block.shape
    scaled = (block - lo) / (hi - lo) * bins
    idx = jnp.clip(scaled.astype(jnp.int32), 0, bins - 1)            # (rows, d)
    flat = jnp.zeros((), jnp.int32)
    for k in range(d):
        flat = flat * bins + idx[:, k]
    counts = jnp.zeros((bins**d,), jnp.int32).at[flat].add(1)
    return counts.reshape((bins,) * d)


def _histogram_kernel_factory(args: tuple, kwargs: dict) -> PartitionKernel | None:
    """Fused-kernel factory: partial(histogramdd_block, bins=, lo=, hi=)."""
    if args or set(kwargs) != {"bins", "lo", "hi"}:
        return None
    bins, lo, hi = kwargs["bins"], kwargs["lo"], kwargs["hi"]

    def supports(stacked_shape: tuple, extra_args: tuple) -> bool:
        # flat one-hot grid: keep the VMEM accumulator (bins**d cells) sane
        d = stacked_shape[-1]
        return not extra_args and bins**d <= 1 << 20

    return PartitionKernel(
        name="partition_histogramdd",
        key=("hist_dd", bins, lo, hi),
        fn=lambda stacked: partition_histogramdd(
            stacked, bins=bins, lo=lo, hi=hi, interpret=pallas_interpret()
        ),
        supports=supports,
    )


register_partition_kernel(histogramdd_block, _histogram_kernel_factory)


def histogram(
    x: BlockedArray,
    *,
    bins: int = 8,
    lo: float = 0.0,
    hi: float = 1.0,
    policy: ExecutionPolicy | str = SplIter(),
    executor: Executor | None = None,
) -> tuple[jax.Array, EngineReport]:
    block_fn = partial(histogramdd_block, bins=bins, lo=lo, hi=hi)
    res = (
        Collection.from_blocked(x)
        .split(as_policy(policy))
        .map_blocks(block_fn)
        .reduce(lambda a, b: a + b)
        .compute(executor=executor)
    )
    return res.value, res.report
