"""The paper's four evaluation applications (§5), on the repro.api layer.

Each app takes ``policy: ExecutionPolicy`` (Baseline / SplIter / Rechunk)
and an optional ``executor`` (LocalExecutor / ThreadedExecutor); legacy
mode strings are still coerced via :func:`repro.api.as_policy`.
"""

from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.apps.knn import knn

__all__ = ["histogram", "kmeans", "cascade_svm", "knn"]
