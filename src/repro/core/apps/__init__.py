"""The paper's four evaluation applications (§5), on the SplIter task engine."""

from repro.core.apps.histogram import histogram
from repro.core.apps.kmeans import kmeans
from repro.core.apps.cascade_svm import cascade_svm
from repro.core.apps.knn import knn

__all__ = ["histogram", "kmeans", "cascade_svm", "knn"]
